"""Batched PPAC + CFP evaluation over encoded populations.

``evaluate_batch`` computes the same six metrics as the scalar
:func:`repro.core.evaluate.evaluate` — latency, energy, area, dollar,
embodied CFP, operational CFP (Eqs. 2-17) — for an entire ``int32``
population at once, within 1e-6 relative tolerance of the scalar
reference (asserted by the tier-1 parity tests and the
``pathfinder_batch`` benchmark).

Three-stage pipeline:

1. **Lookup tables** (built once per (workload, TechDB, tile sizes)):
   per-(array, node, sram) chiplet physicals (area/power/cost/carbon) via
   the scalar :class:`Chiplet` methods, and per-tile prefix-sum tables of
   the ScaleSim-equivalent simulation over the canonical tile list, one
   per (array, sram, dataflow, split-K) combination. Algorithm 1 assigns
   each core a *contiguous* tile range, so a core's simulation result is
   a difference of two prefix entries.
2. **Topology descriptors** (thin Python pass, the only non-vectorized
   stage): the slicing floorplan, link bandwidths, BFS reduction routes
   and DRAM attach points per system — identical math to
   :mod:`repro.core.d2d` including its sorted-BFS tie-breaking.
3. **Array arithmetic**: tile assignment, prefix gathers and the full
   latency/energy/area/dollar/CFP calculation as ``jax.numpy`` gathers
   and arithmetic over ``[population, chiplet-slot]`` arrays (float64 via
   ``jax.experimental.enable_x64``).
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import comm as comm_mod
from repro.core import schedule as sched_mod
from repro.core.carbon import (
    SECONDS_PER_YEAR,
    effective_intensity,
    effective_price,
)
from repro.core.regions import as_region
from repro.core.chiplet import Chiplet
from repro.core.evaluate import Metrics
from repro.core.scalesim import OPERAND_BYTES, PSUM_BYTES
from repro.core.techdb import DEFAULT_DB, HOURS_PER_DAY, TechDB
from repro.core.templates import Normalizer
from repro.core.workload import DEFAULT_TILE, GEMMWorkload, _partition
from repro.pathfinding.space import (
    COL_DATAFLOW,
    COL_MEM,
    COL_N,
    COL_ORDER,
    COL_PAIR25,
    COL_PAIR3,
    COL_SPLITK,
    COL_STACK,
    COL_STYLE,
    DEFAULT_MAX_CHIPLETS,
    DesignSpace,
    S_2D,
    S_3D,
    S_HYBRID,
)

MAX_LINKS = 16  # slicing floorplans of <= 6 planar slots + a 3D chain
_TOPO_CACHE_MAX = 200_000  # per-evaluator memoized topology descriptors


@dataclasses.dataclass
class MetricsBatch:
    """Struct-of-arrays mirror of :class:`repro.core.evaluate.Metrics`."""

    latency_s: np.ndarray
    energy_j: np.ndarray
    area_mm2: np.ndarray
    dollar: np.ndarray
    emb_cfp_kg: np.ndarray
    ope_cfp_kg: np.ndarray
    l_compute_rd_s: np.ndarray
    l_d2d_s: np.ndarray
    l_dram_wr_s: np.ndarray
    e_compute_j: np.ndarray
    e_d2d_j: np.ndarray
    d2d_bits: np.ndarray
    macs: np.ndarray

    def __len__(self) -> int:
        return len(self.latency_s)

    @property
    def total_cfp(self) -> np.ndarray:
        return self.emb_cfp_kg + self.ope_cfp_kg

    def fields(self) -> Dict[str, np.ndarray]:
        """The six Eq. 17 metric fields (METRIC_FIELDS order-compatible)."""
        return {
            "energy_j": self.energy_j, "area_mm2": self.area_mm2,
            "latency_s": self.latency_s, "dollar": self.dollar,
            "emb_cfp_kg": self.emb_cfp_kg, "ope_cfp_kg": self.ope_cfp_kg,
        }

    def objective_vectors(self) -> np.ndarray:
        """``[P, 3]`` multi-objective vectors in
        :data:`repro.core.sa.OBJECTIVE_AXES` order ``(latency_s, dollar,
        total_cfp)`` — the Pareto-archive input."""
        return np.stack(
            [np.asarray(self.latency_s, dtype=np.float64),
             np.asarray(self.dollar, dtype=np.float64),
             np.asarray(self.total_cfp, dtype=np.float64)], axis=1)

    def row(self, i: int) -> Metrics:
        return Metrics(
            latency_s=float(self.latency_s[i]),
            energy_j=float(self.energy_j[i]),
            area_mm2=float(self.area_mm2[i]),
            dollar=float(self.dollar[i]),
            emb_cfp_kg=float(self.emb_cfp_kg[i]),
            ope_cfp_kg=float(self.ope_cfp_kg[i]),
            l_compute_rd_s=float(self.l_compute_rd_s[i]),
            l_d2d_s=float(self.l_d2d_s[i]),
            l_dram_wr_s=float(self.l_dram_wr_s[i]),
            e_compute_j=float(self.e_compute_j[i]),
            e_d2d_j=float(self.e_d2d_j[i]),
            # the scalar fields are exact integers carried in float64;
            # round() instead of int() so an epsilon below the true value
            # (e.g. 41.999...) cannot truncate to the wrong integer
            d2d_bits=int(round(float(self.d2d_bits[i]))),
            macs=int(round(float(self.macs[i]))),
        )


# ---------------------------------------------------------------------------
# Vectorized ScaleSim-equivalent per-tile model (exact integer replication
# of scalesim.simulate_tile / _tile_traffic)
# ---------------------------------------------------------------------------


def _ceil_div(a, b):
    return -(-a // b)


def _tile_sim_arrays(m, k, n, a: int, buf: int, dataflow: str):
    """(cycles, rd_bits, wr_bits, sram_bits, macs) int64 arrays over tiles."""
    m, k, n = (np.asarray(x, dtype=np.int64) for x in (m, k, n))
    if dataflow == "OS":
        passes, stream = _ceil_div(m, a) * _ceil_div(n, a), k
    elif dataflow == "WS":
        passes, stream = _ceil_div(k, a) * _ceil_div(n, a), m
    else:  # IS
        passes, stream = _ceil_div(m, a) * _ceil_div(k, a), n
    cycles = passes * (stream + 2 * a - 1)

    if_b = m * k * OPERAND_BYTES
    w_b = k * n * OPERAND_BYTES
    of_b = m * n * PSUM_BYTES
    final_wr = m * n * OPERAND_BYTES
    if dataflow == "OS":
        if_folds = np.where(a * k * OPERAND_BYTES <= buf, 1, _ceil_div(n, a))
        w_folds = np.where(k * a * OPERAND_BYTES <= buf, 1, _ceil_div(m, a))
        rd = if_b * if_folds + w_b * w_folds
        wr = final_wr
    elif dataflow == "WS":
        if_folds = np.where(m * a * OPERAND_BYTES <= buf, 1, _ceil_div(n, a))
        k_folds = _ceil_div(k, a)
        spill = np.where(m * a * PSUM_BYTES <= buf, 1, k_folds)
        rd = w_b + if_b * if_folds + of_b * (spill - 1)
        wr = of_b * (spill - 1) + final_wr
    else:  # IS
        w_folds = np.where(a * n * OPERAND_BYTES <= buf, 1, _ceil_div(m, a))
        k_folds = _ceil_div(k, a)
        spill = np.where(a * n * PSUM_BYTES <= buf, 1, k_folds)
        rd = if_b + w_b * w_folds + of_b * (spill - 1)
        wr = of_b * (spill - 1) + final_wr
    sram = (if_b + w_b + of_b) * 8 + (rd + wr) * 8
    return cycles, rd * 8, wr * 8, sram, m * k * n


# ---------------------------------------------------------------------------
# Tuple-based replication of the slicing floorplanner: identical arithmetic
# to fp.floorplan / fp.Rect.edge_shared (guarded by the tier-1 parity
# tests), minus the per-Rect object overhead — the descriptor pass runs it
# once per 2.5D/hybrid system.
# ---------------------------------------------------------------------------


def _lean_place(items, x, y, w, h, vertical, out):
    if len(items) == 1:
        out[items[0][0]] = (x, y, w, h)
        return
    ordered = sorted(items, key=lambda t: t[1], reverse=True)
    left, right = [], []
    al = ar = 0.0
    for item in ordered:
        if al <= ar:
            left.append(item)
            al += item[1]
        else:
            right.append(item)
            ar += item[1]
    frac = al / (al + ar)
    if vertical:
        wl = w * frac
        _lean_place(left, x, y, wl, h, False, out)
        _lean_place(right, x + wl, y, w - wl, h, False, out)
    else:
        hl = h * frac
        _lean_place(left, x, y, w, hl, True, out)
        _lean_place(right, x, y + hl, w, h - hl, True, out)


def _lean_floorplan(areas):
    """-> (rect tuples (x, y, w, h) in input order, bbox area)."""
    total = sum(areas) * (1.0 + 0.10)
    side = math.sqrt(total)
    out = [None] * len(areas)
    _lean_place(list(enumerate(areas)), 0.0, 0.0, side, side, True, out)
    width = max(r[0] + r[2] for r in out)
    height = max(r[1] + r[3] for r in out)
    return out, width * height


def _lean_edge(r1, r2, tol=1e-9):
    x1, y1, w1, h1 = r1
    x2, y2, w2, h2 = r2
    if abs(x1 + w1 - x2) < tol or abs(x2 + w2 - x1) < tol:
        lo = y1 if y1 > y2 else y2
        hi = min(y1 + h1, y2 + h2)
        return hi - lo if hi > lo else 0.0
    if abs(y1 + h1 - y2) < tol or abs(y2 + h2 - y1) < tol:
        lo = x1 if x1 > x2 else x2
        hi = min(x1 + w1, x2 + w2)
        return hi - lo if hi > lo else 0.0
    return 0.0


# ---------------------------------------------------------------------------
# The evaluator
# ---------------------------------------------------------------------------

_SIM_METRICS = ("cycles", "rd", "wr", "sram", "macs")


class BatchEvaluator:
    """Precomputed-table batched evaluator for one (workload, db, tiles)."""

    def __init__(self, wl: GEMMWorkload, db: TechDB = DEFAULT_DB,
                 tile_sizes: Tuple[int, int, int] = DEFAULT_TILE,
                 space: Optional[DesignSpace] = None):
        self.wl = wl
        self.db = db
        self.tile_sizes = tile_sizes
        self.space = space or DesignSpace(db)
        # LRU: long multi-workload runs churn topologies, so evict the
        # least-recently-used descriptor instead of refusing new inserts
        self._topo_cache: "OrderedDict[bytes, tuple]" = OrderedDict()
        self._build_chiplet_tables()
        self._build_memory_tables()
        self._build_package_info()
        self._build_tile_tables()

    # -- table construction -------------------------------------------------

    def _build_chiplet_tables(self) -> None:
        sp, db = self.space, self.db
        A, T = len(sp.arrays), len(sp.nodes)
        S = int(sp.n_sram.max())
        shape = (A, T, S)
        self.t_area = np.zeros(shape)
        self.t_static = np.zeros(shape)
        self.t_cost = np.zeros(shape)
        self.t_mfg = np.zeros(shape)
        self.t_buf = np.zeros(shape, dtype=np.int64)
        from repro.core import carbon as carbon_mod
        from repro.core import cost as cost_mod
        for ai, array in enumerate(sp.arrays):
            for ti, node in enumerate(sp.nodes):
                for si, sram in enumerate(db.sram_sizes_kb[array]):
                    c = Chiplet(array, node, sram)
                    self.t_area[ai, ti, si] = c.area_mm2(db)
                    self.t_static[ai, ti, si] = c.static_power_w(db)
                    self.t_cost[ai, ti, si] = cost_mod.chiplet_cost(c, db)
                    self.t_mfg[ai, ti, si] = carbon_mod.chiplet_mfg_cfp(c, db)
                    self.t_buf[ai, ti, si] = c.buffer_bytes_each()
        self.t_freq = np.array([db.freq_ghz(t) for t in sp.nodes])
        self.t_des = np.array(
            [db.node_design_cfp[t] / db.production_volume for t in sp.nodes])
        self.t_sram_e = np.array([db.sram_energy_pj_bit(t) for t in sp.nodes])
        self.t_mac_e = np.array([db.mac_energy_pj(t) for t in sp.nodes])
        # Algorithm 1 line 6 relative compute power (node-scaled frequency)
        self.t_power = np.array(
            [[a * a * db.freq_ghz(t) for t in sp.nodes] for a in sp.arrays])

    def _build_memory_tables(self) -> None:
        mems = [self.db.memories[m] for m in self.space.memories]
        self.m_bw = np.array(
            [m.bw_gbs_per_channel * m.max_channels * 8e9 for m in mems])
        self.m_rd = np.array([m.energy_pj_bit_rd for m in mems])
        self.m_wr = np.array([m.energy_pj_bit_wr for m in mems])
        self.m_cost = np.array([m.cost_usd for m in mems])

    def _build_package_info(self) -> None:
        """Per package-protocol pair: everything the link model consumes."""
        db = self.db

        def info(pkg_name, proto_name):
            pkg = db.packages[pkg_name]
            proto = db.protocols[proto_name]
            return (pkg.bump_pitch_um, pkg.bonding_yield, pkg.cfp_kg_per_mm2,
                    pkg.cost_scale, proto.data_rate_gbps, proto.efficiency,
                    proto.energy_pj_bit, pkg_name in ("Passive", "Active"),
                    proto.hop_latency_s)

        self.p25_info = [info(p, pr) for p, pr in self.space.pairs_25d]
        self.p3_info = [info(p, pr) for p, pr in self.space.pairs_3d]
        # per-pair hop latencies for the heterogeneous-latency hop term
        # (the uniform default never reads these at runtime)
        self.p25_hl = np.array([i[8] for i in self.p25_info])
        self.p3_hl = np.array([i[8] for i in self.p3_info])
        self.hop_uniform = db.uniform_hop_latency()

    def _build_tile_tables(self) -> None:
        """Canonical tile lists (Algorithm 1 lines 1-4) and prefix-sum sim
        tables [array, sram, dataflow, tile+1] for both split-K settings."""
        wl, db, sp = self.wl, self.db, self.space
        t_m, t_k, t_n = self.tile_sizes
        self.tiles: Dict[int, dict] = {}
        for split_k in (0, 1):
            b_k = min(t_k, max(1, wl.K // 2)) if split_k else wl.K
            ms = _partition(wl.M, t_m)
            ks = _partition(wl.K, b_k)
            ns = _partition(wl.N, t_n)
            partial = len(ks) > 1
            mv = np.array([m for m in ms for _ in ks for _ in ns],
                          dtype=np.int64)
            kv = np.array([k for _ in ms for k in ks for _ in ns],
                          dtype=np.int64)
            nv = np.array([n for _ in ms for _ in ks for n in ns],
                          dtype=np.int64)
            T = len(mv)
            A, S = len(sp.arrays), int(sp.n_sram.max())
            pref = {f: np.zeros((A, S, 3, T + 1), dtype=np.int64)
                    for f in _SIM_METRICS}
            for ai, array in enumerate(sp.arrays):
                for si in range(len(db.sram_sizes_kb[array])):
                    buf = int(self.t_buf[ai, 0, si])
                    for di, dataflow in enumerate(("OS", "WS", "IS")):
                        vals = _tile_sim_arrays(mv, kv, nv, array, buf,
                                                dataflow)
                        for f, arr in zip(_SIM_METRICS, vals):
                            np.cumsum(arr, out=pref[f][ai, si, di, 1:])
            width = PSUM_BYTES if partial else OPERAND_BYTES
            mn_pref = np.zeros(T + 1, dtype=np.int64)
            np.cumsum(mv * nv * width * 8, out=mn_pref[1:])
            self.tiles[split_k] = dict(T=T, pref=pref, mn_pref=mn_pref)

    # -- Algorithm 1, vectorized --------------------------------------------

    def _assign(self, powers: np.ndarray, nmask: np.ndarray,
                order: np.ndarray, total: np.ndarray):
        """Per-core (start, count) into the canonical tile list, replicating
        ``tile_and_assign`` exactly (stable sorts, floor + largest-fraction
        leftover distribution)."""
        P, C = powers.shape
        key = np.where(order[:, None] == 0, -powers, powers)
        key = np.where(nmask, key, np.inf)  # padding sorts last either way
        pos = np.argsort(key, axis=1, kind="stable")
        p_sorted = np.take_along_axis(powers, pos, axis=1)
        # accumulate in sorted order, exactly like the scalar loop's
        # sum(): equal-power cores make the fractional parts ulp-level
        # ties, so even summation order is part of the parity contract
        psum = np.add.accumulate(p_sorted, axis=1)[:, -1]
        psum = np.where(psum > 0, psum, 1.0)  # all-padding rows (buckets)
        ideal = p_sorted / psum[:, None] * total[:, None]
        counts = np.floor(ideal)
        remaining = (total - counts.sum(axis=1)).astype(np.int64)
        frac = ideal - counts
        frac_pos = np.argsort(-frac, axis=1, kind="stable")
        rank = np.empty((P, C), dtype=np.int64)
        np.put_along_axis(rank, frac_pos,
                          np.broadcast_to(np.arange(C), (P, C)), axis=1)
        counts = counts.astype(np.int64) + (rank < remaining[:, None])
        starts = np.zeros((P, C), dtype=np.int64)
        np.cumsum(counts[:, :-1], axis=1, out=starts[:, 1:])
        start = np.empty((P, C), dtype=np.int64)
        count = np.empty((P, C), dtype=np.int64)
        np.put_along_axis(start, pos, starts, axis=1)
        np.put_along_axis(count, pos, counts, axis=1)
        return start, count

    # -- stage 2: per-system topology descriptors ---------------------------

    def _topo_one(self, n: int, st: int, ar, p25i: int, p3i: int,
                  stackmask: int, memtot: float):
        """Topology descriptor for one 2.5D or hybrid system — identical
        math to d2d.build_topology/route_reduction, compacted to plain
        tuples so it can be memoized by structural signature."""
        adj: List[List[int]] = [[] for _ in range(n)]
        lidx: Dict[Tuple[int, int], int] = {}
        bw_c: List[int] = []
        bw_v: List[float] = []
        de_c: List[int] = []
        de_v: List[float] = []
        ho_c: List[int] = []
        ho_v: List[int] = []
        ho3_v: List[int] = []   # 3D-kind hops within each source's path
        lkbw: List[float] = []
        lke: List[float] = []
        in_l: List[int] = []
        in_c: List[int] = []
        acost = self.db.assembly_cost

        (pitch25, y25, cfp25, scale25, rate25, eta25, ebit25,
         is_interp, _hl25) = self.p25_info[p25i]
        if st == S_HYBRID:
            (pitch3, y3, cfp3, scale3, rate3, eta3, ebit3,
             _, _hl3) = self.p3_info[p3i]
            members = [i for i in range(n) if (stackmask >> i) & 1]
            order3 = sorted(members, key=lambda i: -ar[i])
            base = order3[0]
            planar = [i for i in range(n)
                      if not (stackmask >> i) & 1] + [base]
            chain = order3
        else:
            base = None
            planar = list(range(n))
            chain = []

        # 2.5D plane: slicing floorplan -> shared-edge links (Eqs. 6-7)
        base_bw = memtot
        rects, bbox = _lean_floorplan([ar[i] for i in planar])
        npl = len(planar)
        for j in range(npl):
            rj = rects[j]
            for j2 in range(j + 1, npl):
                edge = _lean_edge(rj, rects[j2])
                if edge > 1e-9:
                    bw = (rate25 * 1e9
                          * max(1, int(edge * 1e3 / pitch25)) * eta25)
                    a, b = planar[j], planar[j2]
                    for end in (a, b):
                        perim = 4.0 * math.sqrt(ar[end])
                        bw = min(bw, rate25 * 1e9
                                 * max(1, int(perim * 1e3 / pitch25))
                                 * eta25)
                    lidx[(a, b) if a < b else (b, a)] = len(lkbw)
                    lkbw.append(bw)
                    lke.append(ebit25)
                    adj[a].append(b)
                    adj[b].append(a)
        tot = sum(ar[i] for i in planar)
        for i in planar:
            share = memtot * ar[i] / tot
            bw_c.append(i)
            bw_v.append(share)
            if i == base:
                base_bw = share

        # 3D chain: face-area bonds, base-die-mediated DRAM (Eqs. 8-10)
        chain_links = []
        links3 = set()          # link indices of 3D kind (hop-latency split)
        for lo, hi in zip(chain, chain[1:]):
            face = min(ar[lo], ar[hi])
            bw = (rate3 * 1e9
                  * max(1, int(face * 1e6 / (pitch3 * pitch3))) * eta3)
            chain_links.append(bw)
            links3.add(len(lkbw))
            lidx[(lo, hi) if lo < hi else (hi, lo)] = len(lkbw)
            lkbw.append(bw)
            lke.append(ebit3)
            adj[lo].append(hi)
            adj[hi].append(lo)
        # stacked non-base dies reach DRAM only through the base die
        bw = base_bw
        for tier in range(1, len(chain)):
            i = chain[tier]
            bw = min(bw, chain_links[tier - 1])
            bw_c.append(i)
            bw_v.append(bw)
            de_c.append(i)
            de_v.append(tier * ebit3)
        assert len(lkbw) <= MAX_LINKS, "floorplan produced > MAX_LINKS"

        # reduction routes: BFS per source, sorted-neighbour expansion
        # (identical tie-breaking to d2d.Topology.shortest_path). The
        # destination is the first-largest die, as in build_topology.
        d = ar.index(max(ar[:n]))
        adj = [sorted(a) for a in adj]
        for src in range(n):
            if src == d:
                continue
            if d in adj[src]:
                # direct link: the unique length-1 shortest path, so
                # BFS tie-breaking cannot matter — skip the search
                li = lidx[(src, d) if src < d else (d, src)]
                in_l.append(li)
                in_c.append(src)
                ho_c.append(src)
                ho_v.append(1)
                ho3_v.append(1 if li in links3 else 0)
                continue
            prev = {src: src}
            queue = [src]
            qi = 0
            found = False
            while qi < len(queue) and not found:
                u = queue[qi]
                qi += 1
                for w in adj[u]:
                    if w not in prev:
                        prev[w] = u
                        if w == d:
                            found = True
                            break
                        queue.append(w)
            node = d
            nh = 0
            nh3 = 0
            while node != src:
                u = prev[node]
                li = lidx[(u, node) if u < node else (node, u)]
                in_l.append(li)
                in_c.append(src)
                nh += 1
                nh3 += 1 if li in links3 else 0
                node = u
            ho_c.append(src)
            ho_v.append(nh)
            ho3_v.append(nh3)

        # bonding yield, assembly cost, carbon rates (Eqs. 15-16, 2)
        n_attach = len(planar)
        bond_y = y25 ** n_attach
        assembly = n_attach * acost * scale25
        p3_bonded = 0.0
        if st == S_HYBRID:
            n_bonds = max(0, len(chain) - 1)
            bond_y = bond_y * y3 ** n_bonds
            assembly = assembly + len(chain) * acost * scale3
            p3_bonded = cfp3 * sum(ar[i] for i in chain[1:])
        return ((bw_c, bw_v), (de_c, de_v), (ho_c, ho_v, ho3_v), (lkbw, lke),
                (in_l, in_c), bbox, bond_y, assembly, is_interp, cfp25,
                p3_bonded)

    def _topology(self, v: np.ndarray, areas: np.ndarray):
        P, C = areas.shape
        db = self.db
        style = v[:, COL_STYLE]
        is2d = style == S_2D

        # scatter accumulators: per-element numpy writes are ~1us each, so
        # the loop collects plain-python triplets and scatters once at the
        # end (this is the difference between ~2x and ~8x over scalar)
        bw_p, bw_c, bw_v = [], [], []          # eff_bw[p, c] = v
        de_p, de_c, de_v = [], [], []          # dram_e[p, c] = v
        ho_p, ho_c, ho_v = [], [], []          # hops[p, c] = v
        ho3_v = []                             # 3D-kind hops[p, c] = v
        lk_p, lk_l, lk_bw, lk_e = [], [], [], []   # link_bw/link_e[p, l]
        in_p, in_l, in_c = [], [], []          # inc[p, l, c] = 1

        pkg_area = np.zeros(P)
        pkg_area[is2d] = areas[is2d, 0]
        bond_y_l = [1.0] * P
        assembly_l = [0.0] * P
        interp_l = [False] * P
        p25_rate_l = [0.0] * P
        p3_bonded_l = [0.0] * P
        acost = db.assembly_cost

        # pure-3D rows: a vertical chain (no floorplan) — fully vectorized
        is3d = style == S_3D
        if is3d.any():
            r3 = np.nonzero(is3d)[0]
            n3 = v[r3, COL_N]
            C3 = int(n3.max())
            a3 = areas[r3, :C3]
            # stack order: non-increasing area, ties by index (stable)
            order3 = np.argsort(np.where(np.arange(C3)[None, :] < n3[:, None],
                                         -a3, np.inf), axis=1, kind="stable")
            a_sorted = np.take_along_axis(a3, order3, axis=1)
            info3 = np.asarray(
                [i[:7] for i in self.p3_info])[v[r3, COL_PAIR3]]
            pitch3, y3, cfp3, scale3, rate3, eta3, ebit3 = info3.T
            tiermask = np.arange(1, C3)[None, :] < n3[:, None]  # tier >= 1
            # Eq. 7 per bond: bumps over the (smaller) upper die's face
            face = a_sorted[:, 1:]
            nb = np.maximum(
                1.0, np.trunc(face * 1e6 / (pitch3 * pitch3)[:, None]))
            cbw = rate3[:, None] * 1e9 * nb * eta3[:, None]
            base3 = order3[:, 0]
            memtot3 = self.m_bw[v[r3, COL_MEM]]
            pkg_area[r3] = a_sorted[:, 0]
            # Eqs. 8-10: effective DRAM bw = min(base bw, links below)
            eff3 = np.minimum(memtot3[:, None], np.minimum.accumulate(
                np.where(tiermask, cbw, np.inf), axis=1))
            bw_p.extend(r3.tolist())
            bw_c.extend(base3.tolist())
            bw_v.extend(memtot3.tolist())
            tr, tc = np.nonzero(tiermask)
            bw_p.extend(r3[tr].tolist())
            bw_c.extend(order3[tr, tc + 1].tolist())
            bw_v.extend(eff3[tr, tc].tolist())
            de_p.extend(r3[tr].tolist())
            de_c.extend(order3[tr, tc + 1].tolist())
            de_v.extend(((tc + 1) * ebit3[tr]).tolist())
            ho_p.extend(r3[tr].tolist())
            ho_c.extend(order3[tr, tc + 1].tolist())
            ho_v.extend((tc + 1).tolist())
            ho3_v.extend((tc + 1).tolist())   # every chain hop is 3D kind
            lk_p.extend(r3[tr].tolist())
            lk_l.extend(tc.tolist())
            lk_bw.extend(cbw[tr, tc].tolist())
            lk_e.extend(np.broadcast_to(ebit3[:, None],
                                        cbw.shape)[tr, tc].tolist())
            # tier t's reduction route to the base crosses links 0..t-1
            ir, il, it = np.nonzero(
                np.triu(np.ones((C3 - 1, C3 - 1), dtype=bool))[None]
                & tiermask[:, None, :])
            in_p.extend(r3[ir].tolist())
            in_l.extend(il.tolist())
            in_c.extend(order3[ir, it + 1].tolist())
            for p, nn, y, sc, bonded in zip(
                    r3.tolist(), n3.tolist(), y3.tolist(), scale3.tolist(),
                    (cfp3 * np.where(tiermask, a_sorted[:, 1:], 0.0)
                     .sum(axis=1)).tolist()):
                bond_y_l[p] = y ** (nn - 1)
                assembly_l[p] = nn * acost * sc
                p3_bonded_l[p] = bonded

        pkg_area_l = pkg_area.tolist()
        rows = np.nonzero(~is2d & ~is3d)[0].tolist()
        n_l = v[:, COL_N].tolist()
        st_l = style.tolist()
        p25_l = v[:, COL_PAIR25].tolist()
        p3_l = v[:, COL_PAIR3].tolist()
        stack_l = v[:, COL_STACK].tolist()
        mem_l = self.m_bw[v[:, COL_MEM]].tolist()
        areas_l = areas.tolist()

        # memoize descriptors on the structural columns (everything but the
        # mapping triple): application-level SA moves and re-fits over the
        # same population reuse topologies wholesale
        row_nbytes = v.shape[1] * v.itemsize
        vkey = v.copy()
        vkey[:, COL_ORDER] = 0
        vkey[:, COL_DATAFLOW] = 0
        vkey[:, COL_SPLITK] = 0
        key_blob = vkey.tobytes()
        cache = self._topo_cache

        for p in rows:
            key = key_blob[p * row_nbytes:(p + 1) * row_nbytes]
            desc = cache.get(key)
            if desc is None:
                desc = self._topo_one(n_l[p], st_l[p], areas_l[p],
                                      p25_l[p], p3_l[p], stack_l[p],
                                      mem_l[p])
                cache[key] = desc
                if len(cache) > _TOPO_CACHE_MAX:
                    cache.popitem(last=False)  # evict least recently used
            else:
                cache.move_to_end(key)
            (d_bw, d_de, d_ho, d_lk, d_inc, d_area, d_bond, d_asm,
             d_interp, d_p25, d_p3b) = desc
            bw_p.extend([p] * len(d_bw[0]))
            bw_c.extend(d_bw[0])
            bw_v.extend(d_bw[1])
            de_p.extend([p] * len(d_de[0]))
            de_c.extend(d_de[0])
            de_v.extend(d_de[1])
            ho_p.extend([p] * len(d_ho[0]))
            ho_c.extend(d_ho[0])
            ho_v.extend(d_ho[1])
            ho3_v.extend(d_ho[2])
            lk_p.extend([p] * len(d_lk[0]))
            lk_l.extend(range(len(d_lk[0])))
            lk_bw.extend(d_lk[0])
            lk_e.extend(d_lk[1])
            in_p.extend([p] * len(d_inc[0]))
            in_l.extend(d_inc[0])
            in_c.extend(d_inc[1])
            pkg_area_l[p] = d_area
            bond_y_l[p] = d_bond
            assembly_l[p] = d_asm
            interp_l[p] = d_interp
            p25_rate_l[p] = d_p25
            p3_bonded_l[p] = d_p3b

        eff_bw = np.zeros((P, C))
        eff_bw[bw_p, bw_c] = bw_v
        eff_bw[is2d, 0] = self.m_bw[v[is2d, COL_MEM]]
        dram_e = np.zeros((P, C))
        dram_e[de_p, de_c] = de_v
        hops = np.zeros((P, C), dtype=np.int64)
        hops[ho_p, ho_c] = ho_v
        hops3 = np.zeros((P, C), dtype=np.int64)
        hops3[ho_p, ho_c] = ho3_v
        link_bw = np.full((P, MAX_LINKS), np.inf)
        link_bw[lk_p, lk_l] = lk_bw
        link_e = np.zeros((P, MAX_LINKS))
        link_e[lk_p, lk_l] = lk_e
        inc = np.zeros((P, MAX_LINKS, C))
        inc[in_p, in_l, in_c] = 1.0
        assembly = np.asarray(assembly_l)
        assembly[is2d] = acost
        return dict(eff_bw=eff_bw, dram_e=dram_e, hops=hops, hops3=hops3,
                    link_bw=link_bw,
                    link_e=link_e, inc=inc, pkg_area=np.asarray(pkg_area_l),
                    bond_y=np.asarray(bond_y_l), assembly=assembly,
                    interp=np.asarray(interp_l),
                    p25_rate=np.asarray(p25_rate_l),
                    p3_bonded=np.asarray(p3_bonded_l), is2d=is2d)

    # -- stage 3: jax.numpy arithmetic over the population ------------------

    def __call__(self, encoded: np.ndarray) -> MetricsBatch:
        sp, db, wl = self.space, self.db, self.wl
        v = np.atleast_2d(np.asarray(encoded)).astype(np.int64)
        # pad the population to a power-of-two bucket: every row is
        # computed independently, and stable shapes keep jax's op cache
        # warm across differently sized calls
        n_real = v.shape[0]
        bucket = max(64, 1 << (n_real - 1).bit_length())
        if bucket != n_real:
            v = np.vstack(
                [v, np.zeros((bucket - n_real, v.shape[1]), dtype=v.dtype)])
        P, C = v.shape[0], sp.max_chiplets

        n = v[:, COL_N]
        nmask = np.arange(C)[None, :] < n[:, None]
        chip = v[:, 9:9 + 3 * C].reshape(P, C, 3)
        a_idx = np.where(nmask, chip[:, :, 0], 0)
        t_idx = np.where(nmask, chip[:, :, 1], 0)
        s_idx = np.where(nmask, chip[:, :, 2], 0)

        areas = np.where(nmask, self.t_area[a_idx, t_idx, s_idx], 0.0)
        dest = np.where(nmask, areas, -1.0).argmax(axis=1)

        # mesh_noc comm model: per-slot mean NoC hop counts and physical
        # router counts, gathered from the closed-form tables by the
        # encoded (mesh dims, entry placement) columns; neutral (0, 0)
        # slots contribute exactly 0.0 hops / 1.0 routers
        mesh_on = sp.comm == "mesh_noc"
        if mesh_on:
            nocv = v[:, sp.noc_col:sp.noc_col + 2 * C].reshape(P, C, 2)
            h_tab, r_tab = comm_mod.noc_tables()
            mi = np.where(nmask, nocv[:, :, 0], 0)
            ei = np.where(nmask, nocv[:, :, 1], 0)
            noc_h = np.where(nmask, h_tab[mi, ei], 0.0)
            noc_r = np.where(nmask, r_tab[mi], 1.0)

        # Algorithm 1 + prefix-sum gathers of the cached simulations
        powers = np.where(nmask, self.t_power[a_idx, t_idx], 0.0)
        split = v[:, COL_SPLITK]
        total = np.where(split == 1, self.tiles[1]["T"], self.tiles[0]["T"])
        start, count = self._assign(powers, nmask, v[:, COL_ORDER], total)
        end = start + count
        sims = {f: np.zeros((P, C), dtype=np.int64) for f in _SIM_METRICS}
        mn_bits = np.zeros((P, C), dtype=np.int64)
        df = v[:, COL_DATAFLOW]
        for sk in (0, 1):
            rows = np.nonzero(split == sk)[0]
            if not len(rows):
                continue
            tab = self.tiles[sk]
            ai, si = a_idx[rows], s_idx[rows]
            di = np.broadcast_to(df[rows, None], ai.shape)
            st_r, en_r = start[rows], end[rows]
            for f in _SIM_METRICS:
                pref = tab["pref"][f]
                sims[f][rows] = (pref[ai, si, di, en_r]
                                 - pref[ai, si, di, st_r])
            mn_bits[rows] = tab["mn_pref"][en_r] - tab["mn_pref"][st_r]

        topo = self._topology(v, areas)

        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            f8 = lambda x: jnp.asarray(x, dtype=jnp.float64)
            mask = jnp.asarray(nmask)
            cyc, rd, wr = f8(sims["cycles"]), f8(sims["rd"]), f8(sims["wr"])
            sram_b, macs = f8(sims["sram"]), f8(sims["macs"])
            freq = jnp.where(mask, jnp.take(f8(self.t_freq), t_idx), 1.0)
            eff_bw = f8(topo["eff_bw"])
            den_bw = jnp.where(eff_bw > 0, eff_bw, 1.0)

            # Eq. 5 term 1: max_i (L_compute,i + L_DRAM_RD,i)
            l_comp = cyc / (freq * 1e9)
            l_rd = jnp.where(rd > 0, rd / den_bw, 0.0)
            l_cr = jnp.max(l_comp + l_rd, axis=1)

            # Eq. 5 term 2: reduction-phase D2D over shared links (Fig. 4)
            sbits = jnp.where(
                jnp.arange(C)[None, :] == jnp.asarray(dest)[:, None],
                0.0, f8(mn_bits))
            loads = jnp.einsum("plc,pc->pl", f8(topo["inc"]), sbits)
            l_link = jnp.max(loads / f8(topo["link_bw"]), axis=1)
            # per-source hop latency along the reduction path: uniform
            # per-hop latency collapses to the bit-pinned hops * h; mixed
            # protocol latencies split the count by link kind
            if self.hop_uniform is not None:
                path_lat = f8(topo["hops"]) * self.hop_uniform
            else:
                h25 = self.p25_hl[np.maximum(v[:, COL_PAIR25], 0)]
                h3 = self.p3_hl[np.maximum(v[:, COL_PAIR3], 0)]
                path_lat = (f8(topo["hops"] - topo["hops3"]) * f8(h25)[:, None]
                            + f8(topo["hops3"]) * f8(h3)[:, None])
            if mesh_on:
                # src + dest chiplets' mean on-die NoC hops per bit
                noc_hj = f8(noc_h)
                noc_dest = jnp.take_along_axis(
                    noc_hj, jnp.asarray(dest)[:, None], axis=1)
                pair_noc = noc_hj + noc_dest
                path_lat = path_lat + pair_noc * db.noc_hop_latency_s
            hop_term = jnp.max(jnp.where(sbits > 0, path_lat, 0.0), axis=1)
            l_d2d = l_link + hop_term

            # Eq. 5 term 3: DRAM write-back (split-K dependent)
            eff_dest = jnp.take_along_axis(
                eff_bw, jnp.asarray(dest)[:, None], axis=1)[:, 0]
            wr_split = float(wl.M * wl.N * OPERAND_BYTES * 8) / eff_dest
            wr_direct = jnp.max(jnp.where(wr > 0, wr / den_bw, 0.0), axis=1)
            l_wr = jnp.where(jnp.asarray(split) == 1, wr_split, wr_direct)
            latency = l_cr + l_d2d + l_wr

            # energy (Eqs. 12-14)
            mem_idx = jnp.asarray(v[:, COL_MEM])
            m_rd = jnp.take(f8(self.m_rd), mem_idx)[:, None]
            m_wr = jnp.take(f8(self.m_wr), mem_idx)[:, None]
            sram_e = jnp.take(f8(self.t_sram_e), t_idx)
            mac_e = jnp.take(f8(self.t_mac_e), t_idx)
            e_comp_pj = jnp.sum(rd * m_rd + wr * m_wr + sram_b * sram_e
                                + macs * mac_e, axis=1)
            e_mem_d2d_pj = jnp.sum((rd + wr) * f8(topo["dram_e"]), axis=1)
            e_link_pj = jnp.sum(loads * f8(topo["link_e"]), axis=1)
            if mesh_on:
                # traffic-proportional NoC router energy (per bit-hop)
                e_link_pj = e_link_pj + (jnp.sum(sbits * pair_noc, axis=1)
                                         * db.noc_energy_pj_bit)
            e_compute_j = e_comp_pj * 1e-12
            e_d2d_j = (e_link_pj + e_mem_d2d_pj) * 1e-12
            static_w = jnp.where(
                mask, f8(self.t_static[a_idx, t_idx, s_idx]), 0.0)
            e_static_j = jnp.sum(static_w, axis=1) * latency
            energy = e_compute_j + e_d2d_j + e_static_j

            # area, dollar cost (Eqs. 15-16)
            area = f8(topo["pkg_area"])
            chip_cost = jnp.sum(
                jnp.where(mask, f8(self.t_cost[a_idx, t_idx, s_idx]), 0.0),
                axis=1)
            icost = jnp.where(jnp.asarray(topo["interp"]),
                              _interposer_cost_jnp(area, db), 0.0)
            package = db.substrate_cost_mm2 * area + f8(topo["assembly"])
            bond_y = f8(topo["bond_y"])
            active_s = db.lifetime_years * SECONDS_PER_YEAR * db.use_fraction
            runs = db.duty_runs_per_s * active_s
            # regional axes (default-neutral): lifetime electricity bill
            # on the dollar metric, fab-grid factor on embodied, 24h
            # profile-weighted effective intensity on operational.
            # Window-schedule spaces decode the encoded (start, shape)
            # columns to per-row duty loads; the neutral (0, 0) rows
            # reproduce db.load_profile's values bit-for-bit.
            if sp.schedule == "window":
                loads24 = _schedule_loads(v, sp, db)
                eff_price = _effective_rows(
                    db.electricity_price, db.price_profile, loads24)
                eff_ci = _effective_rows(
                    db.carbon_intensity, db.grid_profile, loads24)
            else:
                eff_price = effective_price(
                    db.electricity_price, db.price_profile, db.load_profile)
                eff_ci = effective_intensity(
                    db.carbon_intensity, db.grid_profile, db.load_profile)
            dollar = ((chip_cost + icost + package) / bond_y
                      + jnp.take(f8(self.m_cost), mem_idx)
                      + energy * runs / 3.6e6 * jnp.asarray(eff_price))

            # embodied + operational CFP (Eqs. 2-3); t_mfg already
            # carries the wasted-die + recycling terms (ECO-CHIP)
            mfg_pc = jnp.where(mask, f8(self.t_mfg[a_idx, t_idx, s_idx]), 0.0)
            mfg = jnp.sum(mfg_pc, axis=1)
            des = jnp.sum(jnp.where(mask, jnp.take(f8(self.t_des), t_idx),
                                    0.0), axis=1)
            icfp = jnp.where(
                jnp.asarray(topo["interp"]),
                area * db.interposer_cpa / _nb_yield_jnp(
                    area, db.interposer_defect, db.yield_alpha), 0.0)
            pkg_cfp_multi = (db.substrate_cfp_mm2 * area
                             + f8(topo["p25_rate"]) * area + icfp
                             + f8(topo["p3_bonded"])) / bond_y
            pkg_cfp = jnp.where(jnp.asarray(topo["is2d"]),
                                db.substrate_cfp_mm2 * area, pkg_cfp_multi)
            if mesh_on:
                # structure-proportional router carbon: each chiplet's
                # router share scales with its mesh router count mx*my
                # (1.0 for the neutral (1,1) mesh -> legacy term exactly).
                pkg_cfp = pkg_cfp + db.router_area_frac * jnp.sum(
                    mfg_pc * f8(noc_r), axis=1)
            else:
                pkg_cfp = pkg_cfp + db.router_area_frac * mfg
            emb = (mfg + des + pkg_cfp) * db.emb_factor
            ope = energy * runs / 3.6e6 * jnp.asarray(eff_ci)

            out = [latency, energy, area, dollar, emb, ope, l_cr, l_d2d,
                   l_wr, e_compute_j, e_d2d_j, jnp.sum(loads, axis=1),
                   jnp.sum(macs, axis=1)]
            out = [np.asarray(x)[:n_real] for x in out]
        return MetricsBatch(*out)


def _interposer_cost_jnp(area, db: TechDB):
    """Vectorized ``cost.interposer_cost`` (65nm die of the package area)."""
    import jax.numpy as jnp
    r = db.wafer_diameter_mm / 2.0
    dpw = (math.pi * r * r / area
           - math.pi * db.wafer_diameter_mm / jnp.sqrt(2.0 * area))
    dpw = jnp.maximum(1.0, jnp.trunc(dpw))
    y = _nb_yield_jnp(area, db.interposer_defect, db.yield_alpha)
    return db.interposer_wafer_cost / dpw / y


def _nb_yield_jnp(area, d0: float, alpha: float):
    """Negative-binomial yield, vectorized."""
    return (1.0 + area * d0 / alpha) ** (-alpha)


def _schedule_loads(v: np.ndarray, space: DesignSpace,
                    db: TechDB) -> np.ndarray:
    """``[P, 24]`` per-row duty loads decoded from the encoded
    ``(start_hour, shape_idx)`` schedule columns of a window-schedule
    population (the shape row rolled to the start hour, exactly
    :func:`repro.core.schedule.schedule_load_row` per row)."""
    tab = sched_mod.schedule_tables(db)
    sc = space.sched_col
    start = v[:, sc].astype(np.int64)
    shape = np.clip(v[:, sc + 1], 0, tab.shape[0] - 1).astype(np.int64)
    hrs = np.arange(HOURS_PER_DAY, dtype=np.int64)
    roll = (hrs[None, :] - start[:, None]) % HOURS_PER_DAY
    return np.take_along_axis(tab[shape], roll, axis=1)


def _effective_rows(base: float, profile, loads: np.ndarray):
    """Per-row effective intensity/price under per-row duty loads, in the
    left-to-right hour accumulation order of
    :func:`repro.core.carbon.effective_intensity` so neutral rows are
    bit-identical to the scalar path. A ``None`` profile is the scalar
    ``base`` for every row, bit-for-bit."""
    if profile is None:
        return np.float64(base)
    corr = np.zeros(loads.shape[0], dtype=np.float64)
    for h, p in enumerate(profile):
        corr += (np.float64(p) - np.float64(base)) * loads[:, h]
    return np.float64(base) + corr


# ---------------------------------------------------------------------------
# module-level evaluator cache + functional entry points
# ---------------------------------------------------------------------------

# key -> (db, evaluator). The TechDB is kept as a strong reference so
# its id() cannot be recycled by a new allocation while the entry lives;
# the caches are small and FIFO-bounded (table rebuilds are cheap).
_EVALUATORS: Dict[tuple, Tuple[TechDB, object]] = {}
_EVALUATOR_CACHE_MAX = 16


def evaluator_cache_key(wl: GEMMWorkload, db: TechDB, tile_sizes,
                        space: Optional[DesignSpace]) -> tuple:
    """Key on the *resolved* chiplet bound so space=None and an
    equivalent default DesignSpace share one evaluator (tables + jax
    warmup). The comm model AND its liveness are part of the key: a
    mesh_noc space needs a program with the NoC terms compiled in, and a
    live-NoC space needs the 4-level move program (an env-frozen mesh
    space must not alias onto it). The schedule model and its liveness
    key the same way: a window space carries two extra encoded columns
    and a windowed operational tail, so it must not alias onto a
    fixed-schedule evaluator (or vice versa)."""
    return (wl, id(db), tile_sizes,
            space.max_chiplets if space is not None else
            DEFAULT_MAX_CHIPLETS,
            (space.comm, space.noc_live) if space is not None else
            (comm_mod.resolve_comm(None), False),
            (space.schedule, space.sched_live) if space is not None else
            (sched_mod.resolve_schedule(None), False))


def cached_evaluator(registry: Dict[tuple, Tuple[TechDB, object]],
                     key: tuple, db: TechDB, factory, max_size: int):
    """Shared FIFO-bounded registry lookup for the host and device
    evaluator caches (the id(db) in the key is validated against the
    live object so a recycled id cannot alias a stale entry)."""
    hit = registry.get(key)
    if hit is not None and hit[0] is db:
        return hit[1]
    ev = factory()
    while len(registry) >= max_size:
        registry.pop(next(iter(registry)))
    registry[key] = (db, ev)
    return ev


def get_evaluator(wl: GEMMWorkload, db: TechDB = DEFAULT_DB,
                  tile_sizes: Tuple[int, int, int] = DEFAULT_TILE,
                  space: Optional[DesignSpace] = None) -> BatchEvaluator:
    return cached_evaluator(
        _EVALUATORS, evaluator_cache_key(wl, db, tile_sizes, space), db,
        lambda: BatchEvaluator(wl, db, tile_sizes, space),
        _EVALUATOR_CACHE_MAX)


def evaluate_batch(encoded: np.ndarray, wl: GEMMWorkload,
                   db: TechDB = DEFAULT_DB,
                   tile_sizes: Tuple[int, int, int] = DEFAULT_TILE,
                   space: Optional[DesignSpace] = None) -> MetricsBatch:
    """Batched counterpart of :func:`repro.core.evaluate.evaluate`.

    ``encoded`` is an ``[P, width]`` int array from
    :class:`DesignSpace` (``encode``/``encode_many``/``sample``). Rows
    must encode *valid* systems (check with ``space.validity_mask``).
    """
    return get_evaluator(wl, db, tile_sizes, space)(encoded)


def fit_normalizer_batched(wl: GEMMWorkload, db: TechDB = DEFAULT_DB,
                           samples: int = 10_000, seed: int = 1234,
                           space: Optional[DesignSpace] = None,
                           max_chiplets: int = 6) -> Normalizer:
    """Batched rebuild of :func:`repro.core.sa.fit_normalizer`: sample a
    random valid population in one shot, evaluate it as arrays, fit the
    min/median normalizer (true median, see ``Normalizer.fit_arrays``)."""
    space = space or DesignSpace(db, max_chiplets)
    mb = evaluate_batch(space.sample(samples, key=seed), wl, db, space=space)
    return Normalizer.fit_arrays(mb.fields())


def fit_region_normalizers(wl: GEMMWorkload, regions,
                           db: TechDB = DEFAULT_DB,
                           samples: int = 400, seed: int = 1234,
                           space: Optional[DesignSpace] = None,
                           max_chiplets: int = 6) -> List[Normalizer]:
    """One normalizer per region spec from a *single* batched evaluation.

    ``regions`` entries are bare carbon intensities (floats, the
    historical axis) or :class:`repro.core.regions.Region` specs. Of
    the six Eq. 17 metrics only three depend on the deployment region,
    each as a closed-form rescale of the base evaluation:

    * ``ope_cfp_kg``  = kwh x effective intensity (24h profile-weighted);
    * ``dollar``      = base dollar + kwh x electricity price;
    * ``emb_cfp_kg``  = base embodied x regional fab-grid factor.

    So a region sweep's per-cell normalizer fits — previously one full
    ``evaluate_batch`` per (workload, region) cell — collapse to one
    evaluation of the sample population at the base ``db`` plus exact
    per-region column recomputes (identical operations in identical
    order, so each returned normalizer is bit-identical to a full fit
    under ``dataclasses.replace(db, **region.db_overrides())``; this
    presumes the base ``db`` carries the neutral regional axes, which
    is the default)."""
    space = space or DesignSpace(db, max_chiplets)
    pop = space.sample(samples, key=seed)
    mb = evaluate_batch(pop, wl, db, space=space)
    fields = mb.fields()
    active_s = db.lifetime_years * SECONDS_PER_YEAR * db.use_fraction
    runs = db.duty_runs_per_s * active_s
    energy = np.asarray(fields["energy_j"], dtype=np.float64)
    dollar = np.asarray(fields["dollar"], dtype=np.float64)
    emb = np.asarray(fields["emb_cfp_kg"], dtype=np.float64)
    # window-schedule spaces: per-row duty loads reshape the regional
    # effective intensity/price row-by-row (neutral rows = db.load_profile)
    if space.schedule == "window":
        loads = _schedule_loads(pop.astype(np.int64), space, db)
    else:
        loads = None
    out = []
    for spec in regions:
        r = as_region(spec)
        if loads is None:
            eff = np.float64(effective_intensity(
                r.carbon_intensity, r.grid_profile, db.load_profile))
            eprice = np.float64(effective_price(
                r.electricity_price, r.price_profile, db.load_profile))
        else:
            eff = _effective_rows(r.carbon_intensity, r.grid_profile, loads)
            eprice = _effective_rows(
                r.electricity_price, r.price_profile, loads)
        per_region = dict(fields)
        per_region["ope_cfp_kg"] = energy * runs / 3.6e6 * eff
        per_region["dollar"] = dollar + energy * runs / 3.6e6 * eprice
        per_region["emb_cfp_kg"] = emb * np.float64(r.emb_factor)
        out.append(Normalizer.fit_arrays(per_region))
    return out
