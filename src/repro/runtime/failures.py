"""Fault tolerance: failure injection, restart supervision, stragglers.

At thousand-node scale the mean time between node failures drops below
the job length, so the runtime must treat failure as the steady state:

  * ``FailureInjector`` — deterministic pseudo-random fault schedule
    (per-step hazard) used by tests and the example driver to prove the
    restart path end to end.
  * ``RestartSupervisor`` — wraps the step loop; on a (simulated or real)
    fault it restores the newest valid checkpoint and replays from there.
    Because the data pipeline is step-indexed and stateless, replay is
    exact: no data is skipped or repeated relative to a fault-free run.
  * ``StragglerMonitor`` — tracks per-step wall times in a rolling window;
    steps slower than ``threshold`` x median are flagged. The mitigation
    hook reports the straggling host set so the launcher can re-slice the
    batch (elastic rescale) or evict the host; within a step, the batch
    re-slicing path is exercised by shrinking the active host count.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import Callable, Deque, List, Optional

import numpy as np


class SimulatedFailure(RuntimeError):
    """Raised by the injector at scheduled steps."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministic hazard: fails at steps where hash(seed, step) < rate."""

    rate: float = 0.0
    seed: int = 0
    max_failures: int = 1_000_000

    def __post_init__(self):
        self._failed = 0
        self._fired = set()

    def check(self, step: int) -> None:
        """Faults are transient: a scheduled fault fires once; the replay
        of the same step after restart succeeds (node replaced)."""
        if self.rate <= 0 or self._failed >= self.max_failures:
            return
        if step in self._fired:
            return
        rng = np.random.default_rng((self.seed << 20) ^ step)
        if rng.random() < self.rate:
            self._failed += 1
            self._fired.add(step)
            raise SimulatedFailure(f"injected fault at step {step}")

    @property
    def failures(self) -> int:
        return self._failed


class StragglerMonitor:
    """Rolling-window straggler detection over per-step durations."""

    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self._times: Deque[float] = collections.deque(maxlen=window)
        self.flagged_steps: List[int] = []

    def observe(self, step: int, duration_s: float) -> bool:
        """Record; returns True if this step straggled."""
        is_straggler = False
        if len(self._times) >= max(4, self.window // 4):
            med = statistics.median(self._times)
            if duration_s > self.threshold * med:
                is_straggler = True
                self.flagged_steps.append(step)
        self._times.append(duration_s)
        return is_straggler

    @property
    def median(self) -> Optional[float]:
        return statistics.median(self._times) if self._times else None


@dataclasses.dataclass
class RestartStats:
    restarts: int = 0
    replayed_steps: int = 0
    completed_steps: int = 0
    straggler_steps: int = 0


class RestartSupervisor:
    """Run ``n_steps`` of ``step_fn(step, state) -> state`` under failure
    injection with checkpoint/restart.

    ``save_fn(step, state)`` checkpoints; ``restore_fn() -> (step, state)``
    returns the newest checkpoint (or (0, initial) if none). The supervisor
    guarantees forward progress: the step after a restore re-executes with
    identical data (step-indexed pipeline), so results match a fault-free
    run exactly.
    """

    def __init__(self, step_fn: Callable, save_fn: Callable,
                 restore_fn: Callable, save_every: int,
                 injector: Optional[FailureInjector] = None,
                 monitor: Optional[StragglerMonitor] = None,
                 max_restarts: int = 64):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.save_every = save_every
        self.injector = injector or FailureInjector(0.0)
        self.monitor = monitor or StragglerMonitor()
        self.max_restarts = max_restarts
        self.stats = RestartStats()

    def run(self, n_steps: int, state):
        step = 0
        while step < n_steps:
            try:
                while step < n_steps:
                    self.injector.check(step)
                    t0 = time.monotonic()
                    state = self.step_fn(step, state)
                    dt = time.monotonic() - t0
                    if self.monitor.observe(step, dt):
                        self.stats.straggler_steps += 1
                    self.stats.completed_steps += 1
                    step += 1
                    if step % self.save_every == 0 or step == n_steps:
                        self.save_fn(step, state)
            except SimulatedFailure:
                if self.stats.restarts >= self.max_restarts:
                    raise
                self.stats.restarts += 1
                restored_step, state = self.restore_fn()
                self.stats.replayed_steps += step - restored_step
                step = restored_step
        return state
