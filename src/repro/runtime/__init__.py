from repro.runtime.failures import (
    FailureInjector,
    RestartStats,
    RestartSupervisor,
    SimulatedFailure,
    StragglerMonitor,
)

__all__ = [
    "FailureInjector", "RestartStats", "RestartSupervisor",
    "SimulatedFailure", "StragglerMonitor",
]
