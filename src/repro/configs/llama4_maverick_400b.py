"""llama4-maverick-400b-a17b [moe]: GQA + 128-expert top-1 MoE
interleaved 1:1 with dense layers; early-fusion multimodal (frontend
stubbed). [hf:meta-llama/Llama-4-Scout-17B-16E family; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    moe=True,
    n_experts=128,
    top_k=1,
    n_shared_experts=1,
    moe_d_ff=8192,
    moe_every=2,           # interleaved dense/MoE
    rope_theta=500_000.0,
)
