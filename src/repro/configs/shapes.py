"""Assigned input shapes (the 4 shape cells per LM architecture).

``train_*`` lower ``train_step``; ``prefill_*`` lower the prompt pass;
``decode_*`` / ``long_*`` lower ``serve_step`` — one new token against a
KV cache / recurrent state of the given ``seq_len``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)


def get_shape(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable(cfg, shape: ShapeCell) -> Tuple[bool, str]:
    """(runs?, reason). Encoder-only archs skip decode; full-attention
    archs skip long_500k (needs sub-quadratic attention)."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k":
        if cfg.encoder_only:
            return False, "encoder-only: no decode step"
        if not cfg.sub_quadratic:
            return False, "full softmax attention is O(S) per decode token " \
                          "with an O(S) cache: not sub-quadratic"
    return True, ""
