"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 2:1 pattern
(rglru, rglru, local) x 12 + 2-layer recurrent tail = 38 layers.
[arXiv:2402.19427; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,          # MQA in the attention layers
    d_ff=12288,
    vocab=256000,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    rg_lru_width=4096,
    sub_quadratic=True,    # state is O(window): runs long_500k
    tie_embeddings=True,
)
