"""Architecture configuration schema.

One :class:`ModelConfig` per assigned architecture lives in
``repro/configs/<id>.py``; ``repro.configs.get_config(name)`` resolves
them, and ``.reduced()`` produces the family-preserving small variant the
CPU smoke tests instantiate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attention-free (rwkv)
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None  # defaults to d_model // n_heads

    # dense-attention extras
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0

    # MLA (DeepSeek-V2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1            # 1 = every layer, 2 = interleaved (llama4)
    first_dense: int = 0          # leading dense layers (deepseek)
    dense_d_ff: int = 0           # d_ff of those dense layers
    capacity_factor: float = 1.25

    # hybrid / recurrent (recurrentgemma, rwkv)
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru", "rglru", "local")
    local_window: int = 2048
    rg_conv_width: int = 4
    rg_lru_width: Optional[int] = None    # defaults to d_model

    # structure
    encoder_only: bool = False            # hubert: bidirectional, no decode
    frontend: Optional[str] = None        # None | "audio" | "vision"
    frontend_prefix: int = 0              # prefix embeddings length (vlm)
    tie_embeddings: bool = False

    # runtime
    max_seq: int = 1_048_576
    sub_quadratic: bool = False           # can run long_500k decode
    unroll_layers: bool = False           # python-loop layers (cost probes)

    def __post_init__(self):
        if self.d_head is None and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # -- derived sizes -------------------------------------------------------

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytical parameter count (excludes biases/norms ~<0.1%)."""
        d = self.d_model
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            # rwkv6 time-mix: 5 projections d^2 + ddlerp lora (5-way, r=32)
            # + decay lora (2r) + mixes/bonus; channel mix: 2 d*ff + r-gate
            lora = 32
            per_layer = (5 * d * d + 10 * lora * d + 4 * lora * d
                         + 9 * d) + (2 * d * self.d_ff + d * d + 2 * d)
        else:
            if self.use_mla:
                qd = self.q_lora_rank or d
                h = self.n_heads
                per_layer += d * self.q_lora_rank + qd * h * (
                    self.qk_nope_head_dim + self.qk_rope_head_dim)
                per_layer += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                per_layer += self.kv_lora_rank * h * (
                    self.qk_nope_head_dim + self.v_head_dim)
                per_layer += h * self.v_head_dim * d
            elif self.n_heads:
                dh = self.d_head
                per_layer += d * self.n_heads * dh          # q
                per_layer += 2 * d * self.n_kv_heads * dh   # k, v
                per_layer += self.n_heads * dh * d          # o
            if self.moe:
                moe_layers = ((self.n_layers - self.first_dense)
                              // self.moe_every)
                dense_layers = self.n_layers - moe_layers
                expert = 3 * d * self.moe_d_ff
                moe_p = (self.n_experts + self.n_shared_experts) * expert \
                    + d * self.n_experts
                dff = self.dense_d_ff or self.d_ff
                total_ffn = (moe_layers * moe_p
                             + dense_layers * 3 * d * dff)
                return (emb + self.n_layers * per_layer + total_ffn)
            per_layer += 3 * d * self.d_ff                  # swiglu
        if self.family == "hybrid":
            # mixture of rglru + local-attn layers; approximate with the
            # pattern-weighted average
            pat = self.block_pattern or ("rglru",)
            n_rec = sum(1 for p in pat if p == "rglru") / len(pat)
            w = self.rg_lru_width or d
            rec = 2 * d * w + w * d + 4 * w  # gates + in/out proj + conv
            attn = (d * self.n_heads * self.d_head
                    + 2 * d * self.n_kv_heads * self.d_head
                    + self.n_heads * self.d_head * d)
            per_layer = n_rec * rec + (1 - n_rec) * attn + 3 * d * self.d_ff
        return int(emb + self.n_layers * per_layer)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.use_mla:
            qd = self.q_lora_rank or d
            h = self.n_heads
            per_layer += d * self.q_lora_rank + qd * h * (
                self.qk_nope_head_dim + self.qk_rope_head_dim)
            per_layer += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            per_layer += self.kv_lora_rank * h * (
                self.qk_nope_head_dim + self.v_head_dim)
            per_layer += h * self.v_head_dim * d
        else:
            dh = self.d_head
            per_layer += d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh
            per_layer += self.n_heads * dh * d
        moe_layers = (self.n_layers - self.first_dense) // self.moe_every
        dense_layers = self.n_layers - moe_layers
        expert = 3 * d * self.moe_d_ff
        active = (self.top_k + self.n_shared_experts) * expert
        dff = self.dense_d_ff or self.d_ff
        ffn = moe_layers * active + dense_layers * 3 * d * dff
        return int(emb + self.n_layers * per_layer + ffn)

    # -- reduced smoke variant ------------------------------------------------

    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        pat = self.block_pattern
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, len(pat) or 2),
            d_model=64,
            n_heads=max(1, min(4, self.n_heads)),
            n_kv_heads=max(1, min(2, self.n_kv_heads)),
            d_head=16,
            d_ff=128,
            vocab=128,
            kv_lora_rank=16 if self.use_mla else 0,
            q_lora_rank=24 if self.use_mla else 0,
            qk_nope_head_dim=16 if self.use_mla else self.qk_nope_head_dim,
            qk_rope_head_dim=8 if self.use_mla else self.qk_rope_head_dim,
            v_head_dim=16 if self.use_mla else self.v_head_dim,
            n_experts=8 if self.moe else 0,
            top_k=min(2, self.top_k) if self.moe else 0,
            n_shared_experts=min(1, self.n_shared_experts),
            moe_d_ff=32 if self.moe else 0,
            dense_d_ff=128 if self.dense_d_ff else 0,
            local_window=32,
            rg_lru_width=64 if self.rg_lru_width else None,
            frontend_prefix=min(4, self.frontend_prefix),
            max_seq=512,
        )
