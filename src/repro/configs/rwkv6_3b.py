"""rwkv6-3b [ssm]: Finch — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,             # attention-free
    n_kv_heads=0,
    d_head=0,
    d_ff=8960,
    vocab=65536,
    sub_quadratic=True,    # O(1) state: runs long_500k
)
