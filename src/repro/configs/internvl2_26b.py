"""internvl2-26b [vlm]: InternViT frontend (stubbed patch embeddings) +
InternLM2-20B backbone. [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    frontend="vision",
    frontend_prefix=256,   # precomputed ViT patch embeddings per image
)
