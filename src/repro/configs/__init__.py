"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, ShapeCell, applicable, get_shape

_MODULES: Dict[str, str] = {
    "smollm-135m": "repro.configs.smollm_135m",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "yi-6b": "repro.configs.yi_6b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
}

ARCH_NAMES: Tuple[str, ...] = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


__all__ = ["ModelConfig", "ShapeCell", "SHAPES", "ARCH_NAMES",
           "get_config", "all_configs", "get_shape", "applicable"]
