"""Sharding rules: param, optimizer, activation, and cache partitioning.

Strategy (DP x TP/EP with FSDP-style weight sharding):
  * batch dims           -> ('pod', 'data')        (pure DP; 'pod' = DCN)
  * heads / d_ff / vocab / experts -> 'model'      (TP / EP)
  * the remaining large weight dim -> 'data'       (FSDP; ZeRO-1 falls out
    because optimizer moments mirror param specs leaf-for-leaf)
  * decode caches: sequence axis -> 'model'        (flash-decode: XLA
    turns softmax over the sharded axis into tiny max/sum all-reduces)
  * residual stream between layers -> seq over 'model' (Megatron-style SP,
    set via ``activation_policy``) so remat'd scan carries stay small.

Every rule is *divisibility-aware*: an axis that does not divide a dim is
dropped (replicated) rather than erroring — e.g. internvl2's vocab 92553
stays unsharded while its d_model shards.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes

# Sentinel for "the DP axes of whatever mesh we're on"
DATA = "__data__"


# ---------------------------------------------------------------------------
# Divisibility-aware spec fitting
# ---------------------------------------------------------------------------


def _resolve_axis(entry, mesh) -> Optional[Tuple[str, ...]]:
    if entry is None:
        return None
    if entry == DATA:
        axes = data_axes(mesh)
        return axes if axes else None
    if isinstance(entry, str):
        return (entry,) if entry in mesh.axis_names else None
    return tuple(a for a in entry if a in mesh.axis_names) or None


def fit_spec(shape: Sequence[int], spec: Sequence, mesh: Mesh) -> P:
    """Resolve DATA, drop missing mesh axes and non-dividing entries."""
    out = []
    used = set()
    for dim, entry in zip(shape, spec):
        axes = _resolve_axis(entry, mesh)
        if axes is None:
            out.append(None)
            continue
        axes = tuple(a for a in axes if a not in used)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if size > 1 and dim % size == 0:
            out.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        elif len(axes) > 1:
            # try the largest single axis that divides
            picked = None
            for a in sorted(axes, key=lambda a: -mesh.shape[a]):
                if dim % mesh.shape[a] == 0:
                    picked = a
                    break
            out.append(picked)
            if picked:
                used.add(picked)
        else:
            out.append(None)
    out += [None] * (len(shape) - len(out))
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter rules (matched on the leaf's path suffix)
# ---------------------------------------------------------------------------

# name -> spec by ndim (stacked layer params carry a leading L axis = None)
_PARAM_RULES = [
    # embeddings / heads — vocab over 'model' only: sharding their D dim
    # over 'data' would conflict with batch-over-'data' in the loss einsum
    # and force batch replication of the hidden states.
    (r"embed$", {2: ("model", None)}),
    (r"lm_head$", {2: (None, "model")}),
    # attention
    (r"(wq|wk|wv)$", {3: (None, DATA, "model")}),
    (r"(bq|bk|bv)$", {2: (None, "model")}),
    (r"wo$", {3: (None, "model", DATA)}),
    # MLA
    (r"(w_dq|w_dkv)$", {3: (None, DATA, None)}),
    (r"(w_uq|w_uk|w_uv)$", {3: (None, None, "model")}),
    # FFN (dense 3d, MoE experts 4d: (L, E, D, F))
    (r"(w_gate|w_up)$", {3: (None, DATA, "model"),
                         4: (None, "model", DATA, None)}),
    (r"w_down$", {3: (None, "model", DATA),
                  4: (None, "model", None, DATA)}),
    (r"router$", {3: (None, DATA, None)}),
    # rwkv time/channel mix
    (r"(w_r|w_k|w_v|w_g)$", {3: (None, DATA, "model")}),
    (r"w_o$", {3: (None, "model", DATA)}),
    (r"(lora_a|decay_a)$", {3: (None, DATA, None)}),
    # rglru
    (r"(w_in)$", {3: (None, DATA, "model")}),
    (r"w_out$", {3: (None, "model", DATA)}),
    (r"conv_w$", {3: (None, None, "model")}),
    (r"(conv_b|gate_a_b|gate_x_b|lam)$", {2: (None, "model")}),
    (r"(gate_a|gate_x)$", {4: (None, "model", None, None)}),
]


def _leaf_name(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_spec_for(name: str, shape: Sequence[int], mesh: Mesh) -> P:
    for pattern, by_ndim in _PARAM_RULES:
        if re.search(pattern, name):
            spec = by_ndim.get(len(shape))
            if spec is not None:
                return fit_spec(shape, spec, mesh)
    # default: shard the two largest dims over (model, data) if they divide
    if len(shape) >= 2 and shape[-1] * shape[-2] >= 1 << 20:
        return fit_spec(shape, (None,) * (len(shape) - 2) + (DATA, "model"),
                        mesh)
    return P()


def param_specs(params: Any, mesh: Mesh) -> Any:
    """Pytree of PartitionSpec matching ``params``."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    specs = [param_spec_for(_leaf_name(path), leaf.shape, mesh)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(tdef, specs)


# Serving layout overrides: decode batches are tiny, so expert weights
# keep D full and shard the FFN dim over the dp axes — gate/up matmuls
# become comm-free and only w_down's output needs one small activation
# all-reduce per MoE layer (instead of gathering GBs of expert weights).
_SERVING_OVERRIDES = [
    (r"(w_gate|w_up)$", {4: (None, "model", None, DATA)}),
    (r"w_down$", {4: (None, "model", DATA, None)}),
]


def param_specs_serving(params: Any, mesh: Mesh) -> Any:
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = _leaf_name(path)
        spec = None
        for pattern, by_ndim in _SERVING_OVERRIDES:
            if re.search(pattern, name) and len(leaf.shape) in by_ndim:
                spec = fit_spec(leaf.shape, by_ndim[len(leaf.shape)], mesh)
                break
        specs.append(spec if spec is not None
                     else param_spec_for(name, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(tdef, specs)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh))


# ---------------------------------------------------------------------------
# Batch / activation / cache specs
# ---------------------------------------------------------------------------


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """tokens/labels (B, S) -> (DATA, None); embeds (B, P, D) -> + None."""
    def spec(path, leaf):
        shape = leaf.shape
        return fit_spec(shape, (DATA,) + (None,) * (len(shape) - 1), mesh)
    flat, tdef = jax.tree_util.tree_flatten_with_path(batch)
    return jax.tree_util.tree_unflatten(
        tdef, [spec(p, l) for p, l in flat])


_CACHE_RULES = [
    # stacked KV caches (L, B, T, KV, Dh): seq over model (flash-decode)
    (5, (None, DATA, "model", None, None)),
    # MLA latent (L, B, T, R) / rwkv states (L, B, H, Dk) etc.
    (4, (None, DATA, "model", None)),
    (3, (None, DATA, "model")),
    (2, (None, DATA)),
    (1, (DATA,)),
]


def cache_spec_for(name: str, shape: Sequence[int], mesh: Mesh) -> P:
    if re.search(r"wkv$", name) and len(shape) == 5:
        # rwkv state (L, B, H, Dk, Dv): no seq axis; shard heads if possible
        return fit_spec(shape, (None, DATA, "model", None, None), mesh)
    if re.search(r"conv$", name) and len(shape) == 4:
        # (L, B, K-1, W): channel axis over model
        return fit_spec(shape, (None, DATA, None, "model"), mesh)
    for ndim, spec in _CACHE_RULES:
        if len(shape) == ndim:
            return fit_spec(shape, spec, mesh)
    return P()


def cache_specs(cache: Any, mesh: Mesh) -> Any:
    flat, tdef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        tdef, [cache_spec_for(_leaf_name(p), l.shape, mesh)
               for p, l in flat])


def opt_state_specs(opt_state: Any, pspecs: Any) -> Any:
    """Optimizer moments mirror param specs (ZeRO-1); step is replicated."""
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(), mu=pspecs, nu=pspecs)


# ---------------------------------------------------------------------------
# Activation policy (residual-stream constraint inside scan bodies)
# ---------------------------------------------------------------------------

_policy = threading.local()


@contextlib.contextmanager
def activation_policy(mesh: Mesh, *, seq_axis: Optional[str] = "model",
                      shard_residual_seq: bool = True):
    """While active, :func:`constrain_residual` pins the (B, S, D) residual
    stream to (DATA, seq_axis, None) — Megatron-style sequence sharding of
    the layer boundary, which keeps remat'd scan carries 1/|model| sized."""
    prev = getattr(_policy, "value", None)
    dp = data_axes(mesh)
    _policy.value = {
        "mesh": mesh,
        "spec": (dp if dp else None,
                 seq_axis if shard_residual_seq else None,
                 None),
    }
    try:
        yield
    finally:
        _policy.value = prev


def active_mesh() -> Optional[Mesh]:
    """The mesh of the active activation policy (None outside steps)."""
    pol = getattr(_policy, "value", None)
    return None if pol is None else pol["mesh"]


def constrain_residual(x):
    """Apply the active residual-stream constraint (no-op outside policy)."""
    pol = getattr(_policy, "value", None)
    if pol is None or x.ndim != 3:
        return x
    mesh = pol["mesh"]
    spec = fit_spec(x.shape, pol["spec"], mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain(x, spec_template: Sequence) -> Any:
    pol = getattr(_policy, "value", None)
    if pol is None:
        return x
    mesh = pol["mesh"]
    spec = fit_spec(x.shape, spec_template, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Scenario-axis sharding (pathfinding sweeps)
# ---------------------------------------------------------------------------


def scenario_mesh(min_devices: int = 2) -> Optional[Mesh]:
    """1-D ``('data',)`` mesh over the local devices for sharding a
    scenario (deployment grid) axis — e.g. the stacked
    :class:`repro.pathfinding.device.ScenarioEngine` scan. Returns
    ``None`` when fewer than ``min_devices`` devices exist (sharding a
    single device only adds dispatch overhead). On CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the
    first jax import to expose N virtual devices."""
    from repro.launch.mesh import _mesh_kwargs

    n = len(jax.devices())
    if n < min_devices:
        return None
    return jax.make_mesh((n,), ("data",), **_mesh_kwargs(1))


def shard_scenarios(arrays: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    """Place each array with its *leading* (scenario) axis split over the
    mesh's data axes. Divisibility-aware via :func:`fit_spec`: an axis
    that does not divide the scenario count is dropped (the array is
    replicated) rather than erroring, so ragged grids still run."""
    out = {}
    for k, x in arrays.items():
        spec = fit_spec(x.shape, (DATA,) + (None,) * (x.ndim - 1), mesh)
        out[k] = jax.device_put(x, NamedSharding(mesh, spec))
    return out
