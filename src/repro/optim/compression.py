"""Int8 error-feedback gradient compression for cross-pod all-reduce.

At multi-pod scale the inter-pod (DCN / 'pod'-axis) all-reduce of dense
gradients is the dominant collective. Quantizing per-leaf to int8 with a
per-(row-block) scale cuts those bytes 4x (bf16) to 8x (fp32); the
quantization residual is carried in an error-feedback buffer and added to
the next step's gradient, which keeps SGD-style convergence unbiased in
the long run (error feedback a la 1-bit Adam / EF-SGD).

Usage inside train_step:
    cgrads, new_err = compress_with_feedback(grads, err)
    # all-reduce cgrads over the 'pod' axis (cheap int8 payload)
    grads = decompress(cgrads)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256  # per-block scaling granularity along the leading axis


class Compressed(NamedTuple):
    q: Any        # int8 payloads (params-like)
    scale: Any    # fp32 per-block scales


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape).astype(dtype)


def init_error(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads: Any, err: Any) -> Tuple[Compressed, Any]:
    """Quantize (grad + carried error); the new error is what quantization
    dropped. Returns (compressed, new_error)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = _quantize(target)
        recon = _dequantize(q, scale, g.shape, jnp.float32)
        return (q, scale), target - recon

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qs = jax.tree_util.tree_unflatten(tdef, [o[0][0] for o in outs])
    scales = jax.tree_util.tree_unflatten(tdef, [o[0][1] for o in outs])
    new_err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return Compressed(qs, scales), new_err


def decompress(c: Compressed, like: Any) -> Any:
    flat_q, tdef = jax.tree_util.tree_flatten(c.q)
    flat_s = jax.tree_util.tree_leaves(c.scale)
    flat_l = jax.tree_util.tree_leaves(like)
    outs = [_dequantize(q, s, l.shape, l.dtype)
            for q, s, l in zip(flat_q, flat_s, flat_l)]
    return jax.tree_util.tree_unflatten(tdef, outs)
