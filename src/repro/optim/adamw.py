"""AdamW with warmup-cosine schedule, global-norm clipping, and optional
int8 error-feedback gradient compression (see :mod:`repro.optim.compression`).

Pure-pytree implementation (no optax in the container). Optimizer state
layout mirrors params, so the same sharding rules apply leaf-for-leaf —
ZeRO-1 falls out of sharding the state pytree over 'data'.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray      # ()
    mu: Any                # first moments (params-like)
    nu: Any                # second moments (params-like)


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to lr_min."""
    warm = cfg.lr_peak * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Any, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def apply_updates(params: Any, grads: Any, state: AdamWState,
                  cfg: AdamWConfig = AdamWConfig()):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = (b1 * m.astype(jnp.float32) + (1 - b1) * g32)
        v = (b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32))
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m.astype(cfg.moment_dtype), v.astype(cfg.moment_dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    new_state = AdamWState(step + 1, new_m, new_v)
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
