from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    init,
    schedule,
)
from repro.optim.compression import (
    Compressed,
    compress_with_feedback,
    decompress,
    init_error,
)

__all__ = [
    "AdamWConfig", "AdamWState", "apply_updates", "clip_by_global_norm",
    "global_norm", "init", "schedule",
    "Compressed", "compress_with_feedback", "decompress", "init_error",
]
