from repro.analysis.hlo import collective_bytes, COLLECTIVE_KINDS
