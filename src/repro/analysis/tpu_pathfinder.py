"""TPU carbon pathfinder — the paper's insight applied to the pod (beyond
paper).

CarbonPATH's core move is treating (mapping x architecture x packaging) as
one annealable design vector with carbon as a first-class objective. At
pod scale the isomorphic vector is:

    chips          <-> chiplets         (how much silicon to light up)
    mesh factoring <-> interconnect topology (DP/TP axis split)
    microbatch     <-> tile sizes       (Algorithm 1's t_M)
    remat          <-> dataflow         (recompute vs hold, OS vs WS)
    grad comp.     <-> protocol choice  (bytes per transferred bit)

The evaluator is the same three-term roofline used in SRoofline (compute /
HBM / collective), and the carbon model is ECO-CHIP-style: embodied CFP of
the chips amortized per run + operational CFP from chip power x step time.
The same SA engine as the paper core anneals the plan; ``launch/train.py
--pathfind`` consumes the result.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Tuple

from repro.analysis.roofline import HBM_BW, ICI_LINK_BW, PEAK_FLOPS
from repro.configs.base import ModelConfig

CHIP_POWER_W = 170.0            # TDP-class per chip
CHIP_EMBODIED_KG = 150.0        # embodied CFP per accelerator package
CHIP_LIFETIME_S = 4 * 365.25 * 86400 * 0.6   # 4y at 60% duty
CARBON_INTENSITY = 0.475 / 3.6e6             # kg per J
DCN_BW = 6.25e9                 # bytes/s per chip cross-pod


@dataclasses.dataclass(frozen=True)
class Plan:
    chips: int                  # total chips (power of 2)
    tp: int                     # model-parallel width (divides chips)
    microbatch: int             # per-device batch
    remat: bool
    compress_grads: bool        # int8 cross-pod gradient all-reduce

    @property
    def dp(self) -> int:
        return self.chips // self.tp

    def describe(self) -> str:
        return (f"chips={self.chips} dp={self.dp} tp={self.tp} "
                f"mb={self.microbatch} remat={int(self.remat)} "
                f"int8grads={int(self.compress_grads)}")


@dataclasses.dataclass(frozen=True)
class PlanMetrics:
    step_time_s: float
    energy_j: float
    emb_cfp_kg: float           # amortized per step
    ope_cfp_kg: float           # per step
    hbm_ok: bool

    @property
    def total_cfp(self) -> float:
        return self.emb_cfp_kg + self.ope_cfp_kg


def evaluate_plan(plan: Plan, cfg: ModelConfig, global_batch: int,
                  seq: int) -> PlanMetrics:
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    tokens = global_batch * seq
    # compute term (remat multiplies backward recompute)
    flops = (8.0 if plan.remat else 6.0) * n_active * tokens
    t_compute = flops / (plan.chips * PEAK_FLOPS * 0.5)  # 50% kernel eff.
    # memory term: params + activations traffic per chip
    param_bytes = 2 * n_total / plan.chips * 3          # read + moments
    act_bytes = tokens / plan.dp * cfg.d_model * 2 * cfg.n_layers
    act_bytes *= (1.0 if plan.remat else 2.0)
    t_mem = (param_bytes + act_bytes) / HBM_BW
    # collective term: TP all-reduces + DP gradient reduce
    tp_bytes = 0.0
    if plan.tp > 1:
        tp_bytes = 4 * cfg.n_layers * (tokens / plan.dp) * cfg.d_model * 2
    grad_bytes = 2 * n_active / plan.tp
    if plan.compress_grads:
        grad_bytes /= 4.0                                # int8 + scales
    t_coll = tp_bytes / (plan.chips / plan.dp * ICI_LINK_BW * 2)
    t_coll += grad_bytes / DCN_BW if plan.dp > 1 else 0.0
    step = max(t_compute, t_mem) + t_coll                # comms not hidden
    # HBM capacity check: params+moments+activations must fit 16 GB
    act_resident = (tokens / plan.dp / plan.tp * cfg.d_model * 2
                    * (1 if plan.remat else cfg.n_layers))
    hbm = 16e9 >= (2 + 8) * n_total / plan.chips + act_resident
    energy = plan.chips * CHIP_POWER_W * step
    ope = energy * CARBON_INTENSITY
    emb = plan.chips * CHIP_EMBODIED_KG * (step / CHIP_LIFETIME_S)
    return PlanMetrics(step, energy, emb, ope, hbm)


def pathfind(cfg: ModelConfig, global_batch: int, seq: int,
             *, carbon_weight: float = 0.5, iters: int = 4000,
             seed: int = 0, verbose: bool = False) -> Tuple[Plan, PlanMetrics]:
    """Anneal (chips, tp, microbatch, remat, compression) minimizing
    step_time + carbon_weight * normalized CFP, rejecting OOM plans."""
    rng = random.Random(seed)
    chips_opts = [2 ** i for i in range(4, 14)]          # 16..8192
    tp_opts = [1, 2, 4, 8, 16, 32]

    def random_plan() -> Plan:
        chips = rng.choice(chips_opts)
        tp = rng.choice([t for t in tp_opts if t <= chips])
        mb = rng.choice([1, 2, 4, 8])
        return Plan(chips, tp, mb, rng.random() < 0.5, rng.random() < 0.5)

    def cost(p: Plan) -> float:
        m = evaluate_plan(p, cfg, global_batch, seq)
        if not m.hbm_ok:
            return float("inf")
        # normalize: seconds plus kg scaled into comparable units
        return m.step_time_s * (1 - carbon_weight) + \
            carbon_weight * m.total_cfp * 50.0

    cur = random_plan()
    while math.isinf(cost(cur)):
        cur = random_plan()
    cur_c = cost(cur)
    best, best_c = cur, cur_c
    t = 1.0
    for i in range(iters):
        cand = random_plan() if rng.random() < 0.3 else dataclasses.replace(
            cur,
            tp=rng.choice([x for x in tp_opts if x <= cur.chips]),
            remat=rng.random() < 0.5,
            compress_grads=rng.random() < 0.5)
        c = cost(cand)
        if c < cur_c or rng.random() < math.exp(-(c - cur_c)
                                                / max(t, 1e-9)):
            cur, cur_c = cand, c
            if c < best_c:
                best, best_c = cand, c
        t *= 0.999
    metrics = evaluate_plan(best, cfg, global_batch, seq)
    if verbose:
        print(f"[pathfind] {best.describe()} step={metrics.step_time_s:.4f}s"
              f" cfp/step={metrics.total_cfp*1e3:.3f}g")
    return best, metrics
