"""Three-term roofline analysis from the compiled dry-run.

    compute term    = HLO_FLOPs   / (chips x peak_FLOP/s)
    memory term     = HLO_bytes   / (chips x HBM_bw)
    collective term = coll_bytes  / (chips x link_bw)

Hardware constants target TPU v5e-class chips: 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s per ICI link (x4 links usable per chip on a 2D torus ring;
we charge the per-chip ICI budget at 2 links active per collective phase,
a conservative ring-all-reduce assumption).

HLO FLOPs/bytes come from ``compiled.cost_analysis()`` with one caveat
handled by the dry-run: XLA visits while-loop (lax.scan) bodies ONCE, so
the dry-run compiles each cell at two depths and linearly extrapolates to
the full layer count (exact for scanned stacks — every layer is the same
computation). Collective bytes are parsed from the compiled HLO text and
extrapolated identically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_LINK_BW = 50e9           # bytes/s per link
ICI_LINKS_ACTIVE = 2         # conservative concurrent links per chip


@dataclasses.dataclass(frozen=True)
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float               # per-device HLO FLOPs
    bytes_hbm: float           # per-device HLO bytes accessed
    bytes_coll: float          # per-device collective bytes
    model_flops: float         # 6*N(active)*D useful FLOPs (global)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.bytes_coll / (ICI_LINK_BW * ICI_LINKS_ACTIVE)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lb(self) -> float:
        """Roofline step-time lower bound (max of the three terms —
        perfect overlap assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs_global — how much compiled compute is
        useful (catches remat/attention-waste/dispatch overhead)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_upper_bound(self) -> float:
        """Model FLOPs utilization at the roofline bound: useful FLOPs /
        (chips x peak x step_time_lb)."""
        t = self.step_time_lb
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)


def model_flops_for(cfg, shape) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE) for training; forward-only
    (2*N*D) for prefill; per-token 2*N_active for decode."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * shape.global_batch


def from_record(rec: Dict, cfg, shape) -> Optional[Roofline]:
    if rec.get("status") != "ok":
        return None
    chips = 512 if "multi" in rec["mesh"] else 256
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
        flops=rec["flops"],
        bytes_hbm=rec["bytes_accessed"],
        bytes_coll=rec.get("collectives", {}).get("total", 0.0),
        model_flops=model_flops_for(cfg, shape),
    )


def format_row(r: Roofline) -> str:
    return (f"{r.arch},{r.shape},{r.mesh},{r.t_compute:.3e},"
            f"{r.t_memory:.3e},{r.t_collective:.3e},{r.bottleneck},"
            f"{r.model_flops:.3e},{r.useful_flops_fraction:.3f},"
            f"{r.mfu_upper_bound:.3f}")


HEADER = ("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
          "bottleneck,model_flops,useful_frac,mfu_bound")
