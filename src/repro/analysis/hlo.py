"""HLO analysis: collective-bytes extraction from compiled/lowered text.

``cost_analysis()`` reports FLOPs and bytes but not collective traffic, so
the roofline's collective term comes from parsing the (stable)HLO text:
sum the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# e.g.  %x = bf16[2,4096,128]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
# tuple-result collectives:  (bf16[..], bf16[..]) all-to-all(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)[^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Bytes moved per collective kind (result-shape convention), plus
    op counts as ``<kind>_count``. '-start' ops are counted; their '-done'
    halves are not (avoids double counting async pairs)."""
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async completion: bytes counted at -start
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
            continue
        m = _TUPLE_RE.search(stripped)
        if m:
            shapes, kind = m.groups()
            total = sum(_shape_bytes(d, s)
                        for d, s in _SHAPE_RE.findall(shapes))
            out[kind] += total
            counts[kind] += 1
    result: Dict[str, float] = {}
    for k in COLLECTIVE_KINDS:
        if counts[k]:
            result[k] = out[k]
            result[k + "_count"] = counts[k]
    result["total"] = sum(out.values())
    return result
