"""Depth variants for cost extrapolation.

XLA's HloCostAnalysis visits a while-loop body once, so a lax.scan over L
layers reports ~1 layer of FLOPs. The dry-run therefore compiles each cell
at two reduced depths (d1 < d2, in the arch's natural repeat unit) and
linearly extrapolates FLOPs / bytes / collective-bytes to the full depth —
exact for scanned stacks, since every unit is the identical computation.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.configs.base import ModelConfig


def depth_variants(cfg: ModelConfig) -> Tuple[ModelConfig, int,
                                              ModelConfig, int, int]:
    """Returns (cfg_d1, d1, cfg_d2, d2, full_units).

    Units are scan steps: layers for uniform stacks, (dense, moe) groups
    for llama4, (rglru, rglru, local) groups for recurrentgemma, moe
    layers for deepseek (its single leading dense layer is held constant).
    """
    if cfg.family == "moe" and cfg.moe_every > 1:           # llama4 groups
        unit = cfg.moe_every
        full = cfg.n_layers // unit
        c1 = dataclasses.replace(cfg, n_layers=1 * unit,
                                 unroll_layers=True)
        c2 = dataclasses.replace(cfg, n_layers=2 * unit,
                                 unroll_layers=True)
        return c1, 1, c2, 2, full
    if cfg.family == "moe" and cfg.first_dense:             # deepseek
        fd = cfg.first_dense
        full = cfg.n_layers - fd
        c1 = dataclasses.replace(cfg, n_layers=fd + 1, unroll_layers=True)
        c2 = dataclasses.replace(cfg, n_layers=fd + 2, unroll_layers=True)
        return c1, 1, c2, 2, full
    if cfg.family == "hybrid":                              # rg groups+tail
        pat = len(cfg.block_pattern)
        tail = cfg.n_layers - (cfg.n_layers // pat) * pat
        full = cfg.n_layers // pat
        c1 = dataclasses.replace(cfg, n_layers=1 * pat + tail,
                                 unroll_layers=True)
        c2 = dataclasses.replace(cfg, n_layers=2 * pat + tail,
                                 unroll_layers=True)
        return c1, 1, c2, 2, full
    full = cfg.n_layers
    c1 = dataclasses.replace(cfg, n_layers=1, unroll_layers=True)
    c2 = dataclasses.replace(cfg, n_layers=2, unroll_layers=True)
    return c1, 1, c2, 2, full


def extrapolate(v1: float, v2: float, d1: int, d2: int, full: int) -> float:
    """Linear in depth: f(d) = a + b*d, clamped non-negative (a noisy
    negative slope on a tiny term must not extrapolate below zero)."""
    b = (v2 - v1) / (d2 - d1)
    return max(0.0, v2 + b * (full - d2))
